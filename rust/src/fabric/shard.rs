//! Tile-partitioned (sharded) fabric stepping.
//!
//! The fabric is split into `cfg.shards` horizontal bands of rows, each a
//! contiguous range of PE ids (`base..base + len`). Every per-cycle phase
//! pass runs *within* one shard over one [`ShardCtx`]; the only cross-shard
//! interactions are:
//!
//! - **boundary flits**: a route-phase winner whose downstream router lives
//!   in another shard is appended to the sending shard's [`ShardState::outbox`]
//!   instead of being staged directly. The fabric's coordinator drains all
//!   outboxes between the phase and commit passes (an epoch barrier), so
//!   cross-shard staging never races with the destination shard's own pass.
//! - **boundary acceptance state**: routing decisions that would read a
//!   neighbor router owned by another shard consult a [`PortSnap`] taken at
//!   the previous commit instead ([`ShardCtx::nbr_view`]). Snapshots make
//!   boundary decisions independent of shard stepping order — and therefore
//!   of the host thread count — at the cost of one cycle of staleness on
//!   shard-crossing links (physically: the On/Off wire already has exactly
//!   this one-cycle latency inside a shard, so the model is uniform).
//!
//! Determinism contract: for a **fixed shard count**, results are bit-exact
//! at any thread count (threads only change which host core runs a shard's
//! pass; the epoch barriers serialize every cross-shard effect). Changing
//! the shard count is a *semantic* knob — boundary links switch between
//! live and snapshot acceptance state and PRNG/message-id streams split —
//! so `shards = 1` reproduces the historical single-threaded simulator
//! bit-for-bit, while `shards = k` is a (validated, self-consistent)
//! fabric of its own.
//!
//! The parallel engine lives in `fabric/mod.rs` (`NexusFabric::execute`
//! dispatches on `min(threads, shards)`); this module owns the data types,
//! the per-shard phase/commit passes, and the [`SpinBarrier`] the engine
//! synchronizes on.

use crate::am::Message;
use crate::config::{ArchConfig, ClaimPolicy, ExecPolicy, RoutingPolicy, StepMode, TopologyKind};
use crate::isa::{alu_eval, ConfigEntry, Opcode};
use crate::noc::router::{PortSnap, Router, MAX_PORTS, PORT_LOCAL};
use crate::noc::routing::Dir;
use crate::noc::topology::{link_index, Topology, LINKS_PER_PE};
use crate::pe::{ActiveStream, Pe, StreamMode, OUTQ_CAP};
use crate::trace::{Event, EventKind, PeTraceState, TraceBuffer, TraceConfig};
use crate::util::prng::{stream_seed, SplitMix64};
use std::sync::atomic::{AtomicUsize, Ordering};

use super::active::WakeList;
use super::stats::FabricStats;

/// Message ids are `msg_tag | counter`: the owning shard's index in the top
/// bits, a per-shard counter below. Shard 0's tag is zero, so ids in the
/// single-shard fabric are exactly the historical global counter.
pub(crate) const MSG_TAG_SHIFT: u32 = 48;

/// A route-phase winner bound for a router in another shard, parked in the
/// sending shard's outbox until the epoch barrier drains it.
#[derive(Debug, Clone)]
pub(crate) struct OutFlit {
    /// Destination router id (global).
    pub to: u32,
    /// Destination input port.
    pub port: u8,
    /// Extra commits before the flit lands (`latency - 1`).
    pub wait: u8,
    pub msg: Message,
}

/// Per-shard mutable simulation state: everything a phase pass touches that
/// is not a PE or router in the shard's band.
#[derive(Debug, Clone)]
pub(crate) struct ShardState {
    /// First PE id owned by this shard.
    pub base: usize,
    /// Number of PEs owned.
    pub len: usize,
    /// Per-shard PRNG stream (Valiant hop draws), derived from the config
    /// seed and shard index so streams are independent yet reproducible.
    pub rng: SplitMix64,
    /// Per-shard message-id counter (combined with `msg_tag`).
    pub next_msg_id: u64,
    /// Shard index pre-shifted into the id tag position.
    pub msg_tag: u64,
    /// PEs with pending work, restricted to this shard's band.
    pub awake_pes: WakeList,
    /// Routers holding flits, restricted to this shard's band.
    pub awake_routers: WakeList,
    /// Per-cycle iteration scratch (kept allocation-free).
    pub scratch_pes: Vec<usize>,
    pub scratch_routers: Vec<usize>,
    /// Boundary flits awaiting the epoch-barrier drain.
    pub outbox: Vec<OutFlit>,
    /// Link traversals this shard charged in the current cycle.
    pub link_demand: u64,
    /// Scalar stat deltas accumulated during this shard's passes, merged
    /// into the fabric's global stats at the epoch barrier (the per-PE /
    /// per-link vectors stay empty here: PE stats live on the `Pe`, link
    /// flits are written to a disjoint band slice of the global vector).
    pub stats: FabricStats,
    /// Tracing configuration (a copy of `ArchConfig::trace`; emission gate).
    pub trace: TraceConfig,
    /// This shard's trace ring: events recorded during this epoch, drained
    /// into the fabric sink at the epoch barrier (shard index order).
    pub ring: TraceBuffer,
    /// Last emitted [`PeTraceState`] code per band PE (transition filter).
    pub pe_state: Vec<u8>,
}

impl ShardState {
    pub fn new(index: usize, n: usize, base: usize, len: usize, seed: u64) -> Self {
        ShardState {
            base,
            len,
            rng: SplitMix64::new(stream_seed(seed, index as u64)),
            next_msg_id: 1,
            msg_tag: (index as u64) << MSG_TAG_SHIFT,
            awake_pes: WakeList::new_for_band(n, base, len),
            awake_routers: WakeList::new_for_band(n, base, len),
            scratch_pes: Vec::with_capacity(len),
            scratch_routers: Vec::with_capacity(len),
            outbox: Vec::new(),
            link_demand: 0,
            stats: FabricStats::default(),
            trace: TraceConfig::off(),
            ring: TraceBuffer::new(0),
            pe_state: vec![PeTraceState::Idle as u8; len],
        }
    }

    /// Install the fabric's tracing configuration (sizing the ring).
    pub fn configure_trace(&mut self, trace: TraceConfig) {
        self.trace = trace;
        self.ring = TraceBuffer::new(if trace.enabled { trace.shard_capacity } else { 0 });
        self.pe_state.fill(PeTraceState::Idle as u8);
    }

    /// Record a message-lifecycle event if lifecycle tracing is on.
    #[inline]
    pub fn emit(&mut self, cycle: u64, kind: EventKind, msg: u64, pe: u32, arg: u16) {
        if self.trace.enabled && self.trace.lifecycle {
            self.ring.push(Event { cycle, msg, pe, arg, kind });
        }
    }

    /// Return to the just-constructed state (fabric reset).
    pub fn reset(&mut self, index: usize, seed: u64) {
        self.rng = SplitMix64::new(stream_seed(seed, index as u64));
        self.next_msg_id = 1;
        self.awake_pes.clear();
        self.awake_routers.clear();
        self.outbox.clear();
        self.link_demand = 0;
        self.stats = FabricStats::default();
        self.ring.clear();
        self.pe_state.fill(PeTraceState::Idle as u8);
    }

    /// Allocate the next message id in this shard's stream.
    #[inline]
    pub fn alloc_msg_id(&mut self) -> u64 {
        let id = self.msg_tag | self.next_msg_id;
        self.next_msg_id += 1;
        id
    }
}

/// Everything one shard's phase pass may touch: the shard's own PE/router
/// band mutably, read-only fabric geometry, and the boundary snapshots of
/// *other* shards' ports. Constructed fresh per pass (it is a bundle of
/// reborrows, not storage).
pub(crate) struct ShardCtx<'a> {
    /// This shard's PEs, indexed by `id - shard.base`.
    pub pes: &'a mut [Pe],
    /// This shard's routers, same indexing.
    pub routers: &'a mut [Router],
    pub shard: &'a mut ShardState,
    /// This shard's band of the global per-link flit counters
    /// (`stats.link_flits[base * LINKS_PER_PE ..]`).
    pub link_flits: &'a mut [u64],
    pub cfg: &'a ArchConfig,
    pub config_mem: &'a [ConfigEntry],
    pub nbr_tab: &'a [[u16; MAX_PORTS]],
    pub lat_tab: &'a [[u8; MAX_PORTS]],
    pub topo: &'a dyn Topology,
    pub nports: usize,
    pub torus_bubble: bool,
    /// Owning shard per PE id (boundary test).
    pub shard_of: &'a [u16],
    /// Boundary port snapshots (all shards'; read-only during phases).
    pub snap: &'a [PortSnap],
    /// `snap` entry per `(router, port)`, `u32::MAX` for non-boundary ports.
    pub snap_idx: &'a [u32],
    pub cycle: u64,
}

impl ShardCtx<'_> {
    #[inline]
    fn owns(&self, id: usize) -> bool {
        id >= self.shard.base && id < self.shard.base + self.shard.len
    }

    /// Acceptance state of neighbor router `nbr`'s input `port`: live if the
    /// neighbor is ours, the epoch-start snapshot if it belongs to another
    /// shard.
    #[inline]
    fn nbr_view(&self, nbr: usize, port: usize) -> PortSnap {
        if self.owns(nbr) {
            self.routers[nbr - self.shard.base].port_snap(port)
        } else {
            let k = self.snap_idx[nbr * MAX_PORTS + port];
            debug_assert!(k != u32::MAX, "live read of unregistered boundary port");
            self.snap[k as usize]
        }
    }

    /// Run the three per-cycle phases (PE, en-route, route) over this
    /// shard's band, in the same rotated service order the unsharded
    /// stepper uses (`pivot` visits `base + (cycle % len)` first).
    pub fn run_phases(&mut self) {
        self.shard.link_demand = 0;
        let (base, len) = (self.shard.base, self.shard.len);
        let pivot = base + (self.cycle as usize) % len;
        match self.cfg.step_mode {
            StepMode::DenseOracle => {
                for k in 0..len {
                    self.pe_phase(base + (pivot - base + k) % len);
                }
                if self.cfg.exec == ExecPolicy::EnRoute {
                    for k in 0..len {
                        self.enroute_phase(base + (pivot - base + k) % len);
                    }
                }
                for k in 0..len {
                    self.route_phase(base + (pivot - base + k) % len);
                }
            }
            StepMode::ActiveSet => {
                // Snapshot the awake PEs: wakes during the cycle take effect
                // in the commit pass, matching the dense scan (where a PE's
                // phase has already run by the time later phases hand it
                // new work).
                let mut pe_order = std::mem::take(&mut self.shard.scratch_pes);
                pe_order.clear();
                self.shard.awake_pes.rotated_into(pivot, &mut pe_order);
                for &id in &pe_order {
                    self.pe_phase(id);
                }
                // One router snapshot serves both network phases: the set of
                // routers with *buffered* flits cannot grow mid-cycle
                // (injections and traversals only stage until commit).
                let mut router_order = std::mem::take(&mut self.shard.scratch_routers);
                router_order.clear();
                self.shard.awake_routers.rotated_into(pivot, &mut router_order);
                if self.cfg.exec == ExecPolicy::EnRoute {
                    for &id in &router_order {
                        self.enroute_phase(id);
                    }
                }
                for &id in &router_order {
                    self.route_phase(id);
                }
                self.shard.scratch_pes = pe_order;
                self.shard.scratch_routers = router_order;
            }
        }
    }

    // --- phase 1: PE-local work -------------------------------------------

    fn pe_phase(&mut self, id: usize) {
        let i = id - self.shard.base;
        // Fast path: fully idle PE — only reachable from the dense oracle;
        // the active-set scheduler never visits sleeping PEs.
        if !self.pes[i].has_pending_work() {
            return;
        }
        // Pick at most one message: the decode/ALU handoff (local_redo) has
        // priority; otherwise the inbox, gated by the TIA trigger scheduler.
        let msg = {
            let pe = &mut self.pes[i];
            if let Some(m) = pe.local_redo.take() {
                Some(m)
            } else if pe.trigger_wait > 0 {
                // Operand/trigger wait: work is pending but the triggered-
                // instruction scheduler has not released it yet. Counted on
                // a state-dependent condition both step modes visit
                // identically (the PE has pending work, so it is awake).
                pe.trigger_wait -= 1;
                self.shard.stats.stall_operand_cycles += 1;
                None
            } else if let Some(m) = pe.inbox.take() {
                if self.cfg.trigger_latency > 0 {
                    // Triggered-instruction tag match + priority encode: the
                    // scheduler is busy for trigger_latency further cycles.
                    pe.trigger_wait = self.cfg.trigger_latency;
                    self.shard.stats.trigger_checks += 1;
                }
                Some(m)
            } else {
                None
            }
        };
        if let Some(m) = msg {
            self.process_at(id, m);
        }
        self.stream_phase(id);
        self.inject_phase(id);
    }

    /// Execute a message's current opcode at PE `id` (local work).
    fn process_at(&mut self, id: usize, mut m: Message) {
        let op = m.opcode;
        if op == Opcode::Halt {
            self.retire(id, m);
            return;
        }
        if op.is_alu() {
            debug_assert!(
                !m.op1_is_addr && !m.op2_is_addr,
                "ALU op with unresolved operand at PE{id}: {m:?}"
            );
            let v = alu_eval(op, m.op1, m.op2);
            let entry = self.config_entry(m.n_pc);
            m.morph(v, &entry);
            self.pes[id - self.shard.base].alu_busy = true;
            self.shard.stats.alu_ops += 1;
            self.shard.stats.config_reads += 1;
            self.dispatch(id, m);
        } else {
            self.exec_memory(id, m);
        }
    }

    #[inline]
    fn config_entry(&self, n_pc: u8) -> ConfigEntry {
        *self
            .config_mem
            .get(n_pc as usize)
            .unwrap_or(&ConfigEntry::HALT)
    }

    /// Execute a memory-class opcode on PE `id`'s decode unit (§3.3.1).
    fn exec_memory(&mut self, id: usize, mut m: Message) {
        debug_assert_eq!(
            m.head_dest(),
            Some(id as u16),
            "memory op {:?} at non-owner PE{id}",
            m.opcode
        );
        let i = id - self.shard.base;
        self.shard.stats.mem_ops += 1;
        self.pes[i].stats.mem_ops += 1;
        self.pes[i].decode_busy = true;
        self.shard
            .emit(self.cycle, EventKind::MemOp, m.id, id as u32, m.opcode.encode() as u16);
        match m.opcode {
            Opcode::Load => {
                m.op2 = self.pes[i].dmem[m.op2 as usize];
                self.pes[i].stats.dmem_reads += 1;
                self.shard.stats.dmem_reads += 1;
                m.rotate_dests();
                let e = self.config_entry(m.n_pc);
                m.advance(&e);
                self.shard.stats.config_reads += 1;
                self.dispatch(id, m);
            }
            Opcode::LoadOp1 => {
                m.op1 = self.pes[i].dmem[m.op1 as usize];
                self.pes[i].stats.dmem_reads += 1;
                self.shard.stats.dmem_reads += 1;
                m.rotate_dests();
                let e = self.config_entry(m.n_pc);
                m.advance(&e);
                self.shard.stats.config_reads += 1;
                self.dispatch(id, m);
            }
            Opcode::Store => {
                self.pes[i].dmem[m.result as usize] = m.op1;
                self.pes[i].stats.dmem_writes += 1;
                self.shard.stats.dmem_writes += 1;
                self.retire(id, m);
            }
            Opcode::Accum => {
                let a = m.result as usize;
                let cur = self.pes[i].dmem[a];
                self.pes[i].dmem[a] = (cur as i16).wrapping_add(m.op1 as i16) as u16;
                self.pes[i].stats.dmem_reads += 1;
                self.pes[i].stats.dmem_writes += 1;
                self.shard.stats.dmem_reads += 1;
                self.shard.stats.dmem_writes += 1;
                self.retire(id, m);
            }
            Opcode::AccMin => {
                let a = m.result as usize;
                let cur = self.pes[i].dmem[a] as i16;
                self.pes[i].stats.dmem_reads += 1;
                self.shard.stats.dmem_reads += 1;
                if (m.op1 as i16) < cur {
                    self.pes[i].dmem[a] = m.op1;
                    self.pes[i].stats.dmem_writes += 1;
                    self.shard.stats.dmem_writes += 1;
                    // Conditional re-emission (§3.1: BFS/SSSP relaxation).
                    if let Some((base, count)) = self.pes[i].trigger[a] {
                        let mut t = m;
                        t.rotate_dests();
                        let e = self.config_entry(t.n_pc);
                        t.advance(&e);
                        self.shard.stats.config_reads += 1;
                        self.queue_stream(id, base, count, t);
                    }
                }
                // The message itself always dies; only the stream (if
                // triggered) carries the update onward. Failed relaxations
                // are the paper's "AMs terminate early" case.
                self.retire(id, m);
            }
            Opcode::Stream => {
                let key = m.op2 as usize;
                let mid = m.id;
                let desc = self.pes[i].trigger[key];
                debug_assert!(desc.is_some(), "Stream op with no trigger at PE{id}[{key}]");
                if let Some((base, count)) = desc {
                    m.rotate_dests();
                    let e = self.config_entry(m.n_pc);
                    m.advance(&e);
                    self.shard.stats.config_reads += 1;
                    self.queue_stream(id, base, count, m);
                }
                // The triggering message is consumed by the stream engine.
                self.shard.stats.msgs_retired += 1;
                self.shard.emit(self.cycle, EventKind::Retire, mid, id as u32, 0);
            }
            _ => unreachable!("non-memory opcode {:?} in exec_memory", m.opcode),
        }
    }

    /// Route a message after its op completed: locally (next op owned by
    /// this PE) or out through the AM NIC.
    fn dispatch(&mut self, id: usize, m: Message) {
        if m.opcode == Opcode::Halt || m.ndests == 0 {
            self.retire(id, m);
            return;
        }
        let pe = &mut self.pes[id - self.shard.base];
        if m.head_dest() == Some(id as u16) && pe.local_redo.is_none() {
            // Next op executes here: skip the network (decode/ALU handoff).
            pe.local_redo = Some(m);
        } else {
            pe.outq.push_back(m);
        }
        self.shard.awake_pes.wake(id);
    }

    fn retire(&mut self, id: usize, m: Message) {
        self.shard.stats.msgs_retired += 1;
        self.shard.emit(self.cycle, EventKind::Retire, m.id, id as u32, 0);
    }

    /// Install a streaming decode, or queue it if the engine is busy.
    fn queue_stream(&mut self, id: usize, base: u32, count: u16, template: Message) {
        if count == 0 {
            // Empty stream: the AM "terminates early when it does not find
            // corresponding elements" (§5.1).
            return;
        }
        let s = ActiveStream {
            base,
            remaining: count,
            pos: base,
            template,
        };
        let pe = &mut self.pes[id - self.shard.base];
        if pe.stream.is_none() {
            pe.stream = Some(s);
        } else {
            pe.stream_q.push_back(s);
        }
        self.shard.awake_pes.wake(id);
    }

    /// Advance the streaming decode by one emission (§3.3.1 streaming mode).
    fn stream_phase(&mut self, id: usize) {
        let i = id - self.shard.base;
        if self.pes[i].stream.is_none() {
            let next = self.pes[i].stream_q.pop_front();
            self.pes[i].stream = next;
        }
        if self.pes[i].stream.is_none() {
            return;
        }
        if self.pes[i].outq.len() >= OUTQ_CAP {
            // A live stream blocked on a full NIC queue: backpressure.
            self.shard.stats.stall_backpressure_cycles += 1;
            return;
        }
        let (elem, template, done) = {
            let pe = &mut self.pes[i];
            let s = pe.stream.as_mut().unwrap();
            let elem = pe.stream_mem[s.pos as usize];
            s.pos += 1;
            s.remaining -= 1;
            let done = s.remaining == 0;
            (elem, s.template, done)
        };
        if done {
            self.pes[i].stream = None;
        }
        let mut m = template;
        m.id = self.shard.alloc_msg_id();
        m.birth = self.cycle;
        m.hops = 0;
        m.executed_enroute = false;
        match elem.mode {
            StreamMode::OffsetResult => {
                // Gustavson: output row base + column index; B value in op2.
                m.result = template.result.wrapping_add(elem.aux);
                m.op2 = elem.value as u16;
            }
            StreamMode::PerDest => {
                // Graph/Conv: element names its own destination + address.
                m.dests = [elem.dest_pe, crate::am::NO_DEST, crate::am::NO_DEST];
                m.ndests = 1;
                m.result = elem.aux;
                m.op2 = elem.value as u16;
            }
            StreamMode::OffsetOp1 => {
                // SDDMM: op1 becomes an address (B-column base + k).
                m.op1 = template.op1.wrapping_add(elem.aux);
                m.op2 = elem.value as u16;
            }
        }
        self.shard.stats.stream_emissions += 1;
        self.shard.stats.scanner_ops += 1;
        self.shard.stats.msgs_created += 1;
        self.shard.stats.dmem_reads += 1; // element record fetch
        self.pes[i].stats.stream_emissions += 1;
        self.pes[i].decode_busy = true;
        self.dispatch(id, m);
    }

    /// AM NIC injection (§3.3.1): dynamic AMs first; otherwise the next
    /// static AM from the queue window, gated by router backpressure.
    fn inject_phase(&mut self, id: usize) {
        let i = id - self.shard.base;
        if !self.routers[i].can_inject() {
            // Only a stall if a message was actually waiting to inject
            // (pending work ⇒ the PE is awake in both step modes).
            if !self.pes[i].outq.is_empty() || !self.pes[i].am_window.is_empty() {
                self.shard.stats.stall_inject_cycles += 1;
            }
            return;
        }
        let m = if let Some(m) = self.pes[i].outq.pop_front() {
            Some(m)
        } else if let Some(mut m) = self.pes[i].am_window.pop_front() {
            m.id = self.shard.alloc_msg_id();
            m.birth = self.cycle;
            self.shard.stats.static_injections += 1;
            self.shard.stats.msgs_created += 1;
            self.pes[i].stats.static_injected += 1;
            Some(m)
        } else {
            None
        };
        let Some(mut m) = m else { return };
        if self.cfg.routing == RoutingPolicy::Valiant && m.valiant_hop.is_none() {
            if self.cfg.topology == TopologyKind::Torus2D {
                // Torus Valiant: classic uniformly random intermediate node
                // (VAL [32]); both legs follow shortest-wrap DOR and the
                // bubble flow control keeps each ring deadlock-free, so no
                // rectangle constraint is needed or meaningful on a torus.
                if let Some(dst) = m.head_dest() {
                    let hop = self.shard.rng.below_usize(self.cfg.num_pes()) as u16;
                    if hop != dst && hop as usize != id {
                        m.valiant_hop = Some(hop);
                    }
                }
            }
            // Randomized *minimal-path* load balancing (ROMM [33], the
            // scheme the paper's TIA-Valiant cites): the intermediate hop
            // is drawn inside the minimal rectangle between source and
            // destination, constrained so the composite (src -> hop -> dst)
            // path is monotone in both dimensions AND a legal west-first
            // path — no U-turns, no {N,S}->W turns — which keeps the
            // two-phase route deadlock-free without virtual channels.
            // (Ruche and chiplet fabrics reuse it unchanged: their
            // candidate sets still shrink the same rectangle.)
            else if let Some(dst) = m.head_dest() {
                let (sx, sy) = self.cfg.pe_xy(id);
                let (dx, dy) = self.cfg.pe_xy(dst as usize);
                let (ylo, yhi) = (sy.min(dy), sy.max(dy));
                let rand_y = yhi - ylo; // exclusive range helper below
                let rng = &mut self.shard.rng;
                let (hx, hy) = if dx >= sx {
                    // Eastbound (or same column): any hop in the rectangle.
                    (
                        sx + rng.below_usize(dx - sx + 1),
                        ylo + rng.below_usize(rand_y + 1),
                    )
                } else if rng.chance(0.5) {
                    // Westbound, X-randomized leg: keep y = sy so phase 1
                    // is pure-W and phase 2 (west-first) does W then Y.
                    (dx + rng.below_usize(sx - dx + 1), sy)
                } else {
                    // Westbound, Y-randomized leg: all W moves in phase 1,
                    // phase 2 is pure Y.
                    (dx, ylo + rng.below_usize(rand_y + 1))
                };
                let hop = self.cfg.pe_id(hx, hy) as u16;
                if hop != dst {
                    m.valiant_hop = Some(hop);
                }
            }
        }
        let (mid, dest) = (m.id, m.head_dest().unwrap_or(u16::MAX));
        self.routers[i].stage(PORT_LOCAL, m);
        self.shard.awake_routers.wake(id);
        self.shard.stats.buf_writes += 1;
        self.shard.emit(self.cycle, EventKind::Inject, mid, id as u32, dest);
    }

    // --- phase 2: en-route (opportunistic) execution ------------------------

    /// In-Network Computing (§3.1.3): a PE whose ALU is idle executes the
    /// head flit of one of its router's input ports, if that flit carries an
    /// ALU-class opcode with both operands resolved to values.
    ///
    /// *Which* ready flit (if any) gets claimed is the [`ClaimPolicy`]: a
    /// runtime schedule choice that must stay invariant across step modes.
    /// Active-set stepping only visits routers holding flits while the
    /// dense oracle visits every PE, so a policy may read per-cycle router
    /// state freely but may mutate per-PE policy state **only at a claim**
    /// (claims happen identically in both modes); anything regenerating
    /// per-cycle would diverge.
    fn enroute_phase(&mut self, id: usize) {
        let i = id - self.shard.base;
        if self.pes[i].alu_busy
            || self.routers[i].locked_port.is_some()
            || self.routers[i].inputs.iter().all(|b| b.is_empty())
        {
            return;
        }
        match self.cfg.claim {
            ClaimPolicy::CreditBased => {
                // One claim per credit period per PE: read-only unless the
                // claim lands (last_claim_cycle is written in claim_port).
                let ok = match self.pes[i].last_claim_cycle {
                    None => true,
                    Some(last) => self.cycle - last >= self.cfg.claim_credit_period,
                };
                if !ok {
                    // Claim opportunity suppressed by the credit gate while
                    // flits sit buffered here: claim contention.
                    self.shard.stats.stall_claim_misses += 1;
                    return;
                }
            }
            ClaimPolicy::StealK => {
                // Congestion gate: only buffered flits count (staged flits
                // land at commit, after every phase, in both step modes).
                let occ: usize = self.routers[i].inputs.iter().map(|b| b.len()).sum();
                if occ < self.cfg.claim_steal_threshold {
                    self.shard.stats.stall_claim_misses += 1;
                    return;
                }
            }
            ClaimPolicy::Eager | ClaimPolicy::LocalityBiased => {}
        }
        let start = (self.cycle as usize) % self.nports;
        let mut pick: Option<(usize, usize)> = None; // (port, distance-to-home)
        for k in 0..self.nports {
            let p = (start + k) % self.nports;
            let Some(m) = self.routers[i].inputs[p].head_msg() else {
                continue;
            };
            if !m.alu_ready() || m.head_dest() == Some(id as u16) {
                continue;
            }
            if self.cfg.claim != ClaimPolicy::LocalityBiased {
                // First ready flit in rotated port order wins.
                self.claim_port(id, p);
                return;
            }
            // Locality-biased: scan all ready heads, claim the flit with
            // the longest remaining trip (rotated order breaks ties), since
            // far-from-home flits gain the most from executing here.
            let d = m
                .route_target()
                .map(|t| self.topo.distance(id, t as usize))
                .unwrap_or(0);
            if pick.map(|(_, best)| d > best).unwrap_or(true) {
                pick = Some((p, d));
            }
        }
        if let Some((p, _)) = pick {
            self.claim_port(id, p);
        }
    }

    /// Commit an en-route claim of router `id`'s input port `p`: morph the
    /// head flit in place, lock the port for this cycle, and charge stats.
    fn claim_port(&mut self, id: usize, p: usize) {
        let i = id - self.shard.base;
        let head = self.routers[i].inputs[p].head_msg().unwrap();
        let (entry_pc, mid) = (head.n_pc, head.id);
        let entry = self.config_entry(entry_pc);
        let m = self.routers[i].inputs[p].head_msg_mut().unwrap();
        let v = alu_eval(m.opcode, m.op1, m.op2);
        m.morph(v, &entry);
        m.executed_enroute = true;
        self.routers[i].locked_port = Some(p);
        self.pes[i].alu_busy = true;
        self.pes[i].last_claim_cycle = Some(self.cycle);
        // The claim must reach this cycle's commit pass (to latch the
        // busy flag into stats and clear it), so the PE joins the
        // wake-list even if it holds no messages of its own.
        self.shard.awake_pes.wake(id);
        self.pes[i].stats.enroute_ops += 1;
        self.shard.stats.alu_ops += 1;
        self.shard.stats.enroute_ops += 1;
        self.shard.stats.config_reads += 1;
        self.shard.emit(self.cycle, EventKind::Claim, mid, id as u32, p as u16);
    }

    // --- phase 3: routing ---------------------------------------------------

    fn route_phase(&mut self, id: usize) {
        let i = id - self.shard.base;
        // Fast path: nothing buffered, nothing to route.
        if self.routers[i].inputs.iter().all(|b| b.is_empty()) {
            return;
        }
        let nports = self.nports;
        // Clear Valiant hops that reached their intermediate router.
        if self.cfg.routing == RoutingPolicy::Valiant {
            for p in 0..nports {
                if let Some(m) = self.routers[i].inputs[p].head_msg_mut() {
                    if m.valiant_hop == Some(id as u16) {
                        m.valiant_hop = None;
                    }
                }
            }
        }
        // Route computation: desired output direction per input port, asked
        // of the topology (the mesh path delegates to the original
        // west-first/XY functions bit-for-bit).
        let mut want: [Option<Dir>; MAX_PORTS] = [None; MAX_PORTS];
        for p in 0..nports {
            if self.routers[i].locked_port == Some(p) {
                continue; // being executed en-route this cycle
            }
            let Some(m) = self.routers[i].inputs[p].head_msg() else {
                continue;
            };
            let Some(target) = m.route_target() else {
                // No destination left: drop defensively (should not happen).
                debug_assert!(false, "routed message without destination");
                continue;
            };
            let t = target as usize;
            if t == id {
                want[p] = Some(Dir::Local);
                continue;
            }
            let dir = match self.cfg.routing {
                RoutingPolicy::Xy => self.topo.route_deterministic(id, t),
                // Valiant phases ride the same turn rules; with the hop
                // constraint above, the composite path stays legal.
                RoutingPolicy::Valiant | RoutingPolicy::TurnModelAdaptive => {
                    let mut cands = [Dir::Local; 2];
                    let n = self.topo.route_candidates(id, t, &mut cands);
                    debug_assert!(n >= 1);
                    // Congestion-aware adaptive choice: among permitted
                    // turns, prefer a downstream that can accept now, then
                    // the one with more free buffer space. Cross-shard
                    // downstreams score against their epoch-start snapshot.
                    let score = |d: Dir| {
                        let nbr = self.nbr_tab[id][d.port()] as usize;
                        let v = self.nbr_view(nbr, d.opposite_port());
                        (v.can_accept(), v.effective_free())
                    };
                    if n == 1 {
                        cands[0]
                    } else {
                        let (s0, s1) = (score(cands[0]), score(cands[1]));
                        if s1 > s0 {
                            cands[1]
                        } else {
                            cands[0]
                        }
                    }
                }
            };
            want[p] = Some(dir);
        }
        // Separable allocation: each output port arbitrates among requesting
        // input ports with a rotating priority pointer (Fig 8d). A request
        // mask skips output ports nobody asked for.
        let mut requested = [false; MAX_PORTS];
        for w in want.iter().flatten() {
            requested[w.port()] = true;
        }
        let mut moved = [false; MAX_PORTS];
        for out in 0..nports {
            if !requested[out] {
                continue;
            }
            let start = self.routers[i].rr_ptr[out];
            let mut winner = None;
            for k in 0..nports {
                let p = (start + k) % nports;
                if want[p].map(|d| d.port()) == Some(out) {
                    winner = Some(p);
                    break;
                }
            }
            let Some(p) = winner else { continue };
            let dir = want[p].unwrap();
            // Crossbar traversal if downstream accepts. On a torus the
            // bubble rule applies: a flit continuing along the same
            // direction may transit into any non-full buffer (ignoring
            // On/Off), while a flit *entering* a ring (injection or turn)
            // must leave one extra slot free — the classic bubble flow
            // control that keeps each wraparound ring deadlock-free.
            let ok = if out == PORT_LOCAL {
                self.pes[i].inbox.is_none()
            } else {
                let nbr = self.nbr_tab[id][dir.port()] as usize;
                let v = self.nbr_view(nbr, dir.opposite_port());
                if self.torus_bubble && p == dir.opposite_port() {
                    v.can_transit()
                } else if self.torus_bubble {
                    v.can_accept() && v.effective_free() >= 2
                } else {
                    v.can_accept()
                }
            };
            if !ok {
                // An allocated crossbar winner its downstream refused:
                // buffer backpressure (the flit exists in both step modes,
                // so the count is schedule-invariant).
                self.shard.stats.stall_backpressure_cycles += 1;
                continue;
            }
            let mut m = self.routers[i].pop_port(p).unwrap();
            m.hops += 1;
            let mid = m.id;
            if out == PORT_LOCAL {
                self.pes[i].inbox = Some(m);
                self.shard.awake_pes.wake(id);
            } else {
                let nbr = self.nbr_tab[id][dir.port()] as usize;
                let dport = dir.opposite_port();
                // Multi-cycle links (chiplet crossings) park the flit in the
                // staging slot for `latency - 1` extra commits, modelling
                // both the added latency and the reduced link bandwidth.
                let lat = self.lat_tab[id][dir.port()];
                if self.owns(nbr) {
                    if lat > 1 {
                        self.routers[nbr - self.shard.base].stage_delayed(dport, m, lat - 1);
                    } else {
                        self.routers[nbr - self.shard.base].stage(dport, m);
                    }
                    self.shard.awake_routers.wake(nbr);
                } else {
                    // Boundary crossing: park in the outbox; the epoch
                    // barrier stages it into the destination shard.
                    self.shard.outbox.push(OutFlit {
                        to: nbr as u32,
                        port: dport as u8,
                        wait: lat - 1,
                        msg: m,
                    });
                }
                self.shard.stats.flit_hops += 1;
                self.shard.stats.buf_writes += 1;
                self.link_flits[link_index(id, dir) - self.shard.base * LINKS_PER_PE] += 1;
                self.shard.link_demand += 1;
            }
            self.shard.emit(self.cycle, EventKind::Hop, mid, id as u32, out as u16);
            self.routers[i].rr_ptr[out] = (p + 1) % nports;
            moved[p] = true;
        }
        self.routers[i].sample_stats(&moved);
    }
}

/// Everything one shard's commit pass may touch: the shard's band plus its
/// own range of the boundary snapshot table (refreshed here, at the epoch
/// barrier, for the next cycle's cross-shard reads).
pub(crate) struct CommitCtx<'a> {
    pub pes: &'a mut [Pe],
    pub routers: &'a mut [Router],
    pub shard: &'a mut ShardState,
    /// This shard's slice of the snapshot table (`snap[snap_base..]`).
    pub snap: &'a mut [PortSnap],
    /// `(router id, port)` per owned snapshot entry, same slicing.
    pub snap_src: &'a [(u16, u8)],
    /// Global `snap` index range of each router's entries.
    pub snap_router_range: &'a [(u32, u32)],
    /// Global index of `snap[0]` / `snap_src[0]`.
    pub snap_base: usize,
    pub step_mode: StepMode,
    pub cycle: u64,
}

impl CommitCtx<'_> {
    /// Commit this shard's routers and PEs (staged flits land, busy flags
    /// latch, wake-lists retire idle members) and refresh the boundary
    /// snapshots of every router whose exported state may have changed.
    pub fn run_commit(&mut self) {
        let (base, len) = (self.shard.base, self.shard.len);
        match self.step_mode {
            StepMode::DenseOracle => {
                for id in base..base + len {
                    self.commit_router(id);
                    self.commit_pe(id);
                }
            }
            StepMode::ActiveSet => {
                // Commit runs over the *current* wake-lists — including
                // components woken this cycle — and retires anything left
                // with no work.
                let mut order = std::mem::take(&mut self.shard.scratch_routers);
                order.clear();
                self.shard.awake_routers.snapshot_into(&mut order);
                for &id in &order {
                    self.commit_router(id);
                }
                self.shard.scratch_routers = order;
                let mut order = std::mem::take(&mut self.shard.scratch_pes);
                order.clear();
                self.shard.awake_pes.snapshot_into(&mut order);
                for &id in &order {
                    self.commit_pe(id);
                }
                self.shard.scratch_pes = order;
            }
        }
    }

    /// Commit one router, update its wake-list residency, and refresh its
    /// boundary snapshots. `dirty` is captured *before* `commit` (which
    /// consumes it): a router's exported acceptance state (buffers, staging,
    /// On/Off) only changes at a commit where it was dirty, and every dirty
    /// router is on the wake-list, so this refresh covers all changes.
    #[inline]
    fn commit_router(&mut self, id: usize) {
        let i = id - self.shard.base;
        let was_dirty = self.routers[i].dirty;
        self.routers[i].commit();
        if self.routers[i].occupancy() == 0 {
            self.shard.awake_routers.sleep(id);
        }
        if was_dirty {
            let (s, e) = self.snap_router_range[id];
            for k in s as usize..e as usize {
                let (rid, port) = self.snap_src[k - self.snap_base];
                debug_assert_eq!(rid as usize, id);
                self.snap[k - self.snap_base] =
                    self.routers[i].port_snap(port as usize);
            }
        }
    }

    /// Latch one PE's busy flags into its statistics, clear them for the
    /// next cycle, and update its wake-list residency.
    #[inline]
    fn commit_pe(&mut self, id: usize) {
        let i = id - self.shard.base;
        let (alu, decode) = {
            let pe = &mut self.pes[i];
            let latched = (pe.alu_busy, pe.decode_busy);
            if pe.alu_busy {
                pe.stats.alu_busy_cycles += 1;
            }
            if pe.alu_busy || pe.decode_busy {
                pe.stats.busy_cycles += 1;
            }
            pe.alu_busy = false;
            pe.decode_busy = false;
            latched
        };
        if alu || decode {
            self.shard.stats.active_pe_cycles += 1;
        }
        let pending = self.pes[i].has_pending_work();
        if !pending {
            self.shard.awake_pes.sleep(id);
        }
        if self.shard.trace.enabled {
            // One AluCommit per latched ALU cycle: per PE, AluCommit +
            // MemOp event counts equal `per_pe_committed_ops` exactly.
            if self.shard.trace.lifecycle && alu {
                self.shard.ring.push(Event {
                    cycle: self.cycle,
                    msg: 0,
                    pe: id as u32,
                    arg: 0,
                    kind: EventKind::AluCommit,
                });
            }
            if self.shard.trace.pe_states {
                let st = if alu || decode {
                    PeTraceState::Compute
                } else if pending {
                    PeTraceState::Blocked
                } else {
                    PeTraceState::Idle
                };
                if self.shard.pe_state[i] != st as u8 {
                    self.shard.pe_state[i] = st as u8;
                    self.shard.ring.push(Event {
                        cycle: self.cycle,
                        msg: 0,
                        pe: id as u32,
                        arg: st as u16,
                        kind: EventKind::PeState,
                    });
                }
            }
        }
    }
}

/// A reusable sense-counting spin barrier for the parallel epoch loop.
///
/// `std::sync::Barrier` parks threads in the OS; at four rendezvous per
/// simulated cycle the wake latency dominates the cycle itself. Epoch gaps
/// here are microseconds, so spinning (with `spin_loop` hints) is the right
/// trade. Generation counting makes the barrier safely reusable: a thread
/// cannot enter wait `g + 1` before every thread has observed the release
/// of wait `g`.
pub(crate) struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
        }
    }

    /// Block (spinning) until all `n` participants have called `wait`.
    /// Release/Acquire pairing on `generation` makes every write before any
    /// participant's `wait` visible to every participant after it.
    pub fn wait(&self) {
        let g = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) == self.n - 1 {
            // Last arrival: reset the count and open the next generation.
            self.count.store(0, Ordering::Release);
            self.generation.store(g.wrapping_add(1), Ordering::Release);
        } else {
            while self.generation.load(Ordering::Acquire) == g {
                std::hint::spin_loop();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_msg_ids_are_tagged_and_disjoint() {
        let mut s0 = ShardState::new(0, 8, 0, 4, 42);
        let mut s1 = ShardState::new(1, 8, 4, 4, 42);
        // Shard 0's stream is the historical global counter (tag = 0).
        assert_eq!(s0.alloc_msg_id(), 1);
        assert_eq!(s0.alloc_msg_id(), 2);
        // Shard 1's ids carry its tag; streams never collide.
        let id = s1.alloc_msg_id();
        assert_eq!(id >> MSG_TAG_SHIFT, 1);
        assert_eq!(id & ((1 << MSG_TAG_SHIFT) - 1), 1);
        // Distinct seed-derived PRNG streams.
        assert_ne!(s0.rng.next_u64(), s1.rng.next_u64());
    }

    #[test]
    fn shard_reset_restores_fresh_state() {
        let mut s = ShardState::new(1, 8, 4, 4, 7);
        let fresh_draw = s.rng.clone().next_u64();
        s.alloc_msg_id();
        s.rng.next_u64();
        s.awake_pes.wake(5);
        s.link_demand = 3;
        s.stats.alu_ops = 9;
        s.reset(1, 7);
        assert_eq!(s.next_msg_id, 1);
        assert_eq!(s.rng.clone().next_u64(), fresh_draw);
        assert!(s.awake_pes.is_empty());
        assert_eq!(s.link_demand, 0);
        assert_eq!(s.stats.alu_ops, 0);
    }

    #[test]
    fn spin_barrier_synchronizes_and_reuses() {
        use std::sync::atomic::AtomicU64;
        const ROUNDS: usize = 64;
        const THREADS: usize = 4;
        let barrier = SpinBarrier::new(THREADS);
        let counter = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..THREADS {
                scope.spawn(|| {
                    for round in 0..ROUNDS {
                        counter.fetch_add(1, Ordering::Relaxed);
                        barrier.wait();
                        // Every thread must observe all increments of this
                        // round before any thread starts the next one.
                        let seen = counter.load(Ordering::Relaxed);
                        assert!(
                            seen >= ((round + 1) * THREADS) as u64,
                            "barrier leaked: saw {seen} in round {round}"
                        );
                        barrier.wait();
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), (ROUNDS * THREADS) as u64);
    }
}
