//! Golden-model cross-validation: fabric (INT16 cycle-accurate) vs the
//! AOT-compiled XLA artifacts (f32, lowered from JAX + Pallas by
//! `python/compile/aot.py`).
//!
//! Three-way agreement per kernel:
//!
//! 1. software reference (`tensor::*`, wrapping INT16) —
//! 2. XLA golden model (`artifacts/<name>.hlo.txt` via PJRT) —
//! 3. the Nexus fabric itself.
//!
//! Workload values are generated small (|v| <= 4, short reductions) so the
//! INT16 and f32 computations are exactly equal after rounding; any
//! disagreement is a real functional bug in one of the layers.
//!
//! Artifact shapes are fixed at AOT time (XLA requires static shapes):
//!
//! | artifact    | shapes                                   |
//! |-------------|------------------------------------------|
//! | `spmv_ell`  | values `f32[64,32]`, colidx `f32[64,32]`, x `f32[64]` |
//! | `sddmm`     | mask `f32[32,32]`, a `f32[32,16]`, b `f32[16,32]`     |
//! | `matmul`    | a `f32[24,24]`, b `f32[24,24]`               |
//! | `spmadd`    | a `f32[64,64]`, b `f32[64,64]`               |

use crate::config::ArchConfig;
use crate::machine::{Compiled, Machine};
use crate::runtime::{GoldenRuntime, Result};
use crate::tensor::{gen, Csr, Ell};
use crate::util::SplitMix64;
use crate::workloads::Built;
use std::path::Path;

/// Fixed artifact shapes (must match `python/compile/aot.py`).
pub const SPMV_ROWS: usize = 64;
pub const SPMV_COLS: usize = 64;
pub const SPMV_ELL_WIDTH: usize = 32;
pub const SDDMM_M: usize = 32;
pub const SDDMM_K: usize = 16;
pub const SDDMM_N: usize = 32;
pub const MATMUL_N: usize = 24;
pub const SPMADD_N: usize = 64;

fn to_f32(v: &[i16]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

fn cmp_f32_i16(xla: &[f32], reference: &[i16], what: &str) -> Result<()> {
    if xla.len() != reference.len() {
        return Err(format!("{what}: length {} vs {}", xla.len(), reference.len()).into());
    }
    for (i, (x, r)) in xla.iter().zip(reference).enumerate() {
        if (x - *r as f32).abs() > 0.5 {
            return Err(format!("{what}: mismatch at [{i}]: xla {x} vs reference {r}").into());
        }
    }
    Ok(())
}

/// Execute a fabric program through the `Machine` API, returning its
/// validated outputs.
fn run_fabric(cfg: ArchConfig, built: Built) -> Result<Vec<i16>> {
    let mut m = Machine::new(cfg);
    let exec = m
        .execute(&Compiled::from_built(built))
        .map_err(|e| e.to_string())?;
    Ok(exec.outputs)
}

/// Run all golden checks. Each row is (kernel, status). Kernels whose
/// artifact is missing — or whose runtime is the feature-gated stub — are
/// reported as skipped rather than failing, so the simulator test-suite
/// stays runnable before `make artifacts` and without the `pjrt` feature.
pub fn check_all(dir: &Path, seed: u64) -> Result<Vec<(String, String)>> {
    let mut rt = GoldenRuntime::new(dir)?;
    let mut rows = Vec::new();
    for (name, f) in [
        ("spmv_ell", check_spmv as fn(&mut GoldenRuntime, u64) -> Result<()>),
        ("sddmm", check_sddmm),
        ("matmul", check_matmul),
        ("spmadd", check_spmadd),
    ] {
        if !rt.has_artifact(name) {
            rows.push((name.to_string(), "SKIPPED (no artifact)".to_string()));
            continue;
        }
        if !rt.available() {
            rows.push((
                name.to_string(),
                "SKIPPED (built without the `pjrt` feature)".to_string(),
            ));
            continue;
        }
        f(&mut rt, seed).map_err(|e| format!("golden check {name}: {e}"))?;
        rows.push((
            name.to_string(),
            "OK (reference == XLA == fabric)".to_string(),
        ));
    }
    Ok(rows)
}

fn check_spmv(rt: &mut GoldenRuntime, seed: u64) -> Result<()> {
    let mut rng = SplitMix64::new(seed ^ 0x51);
    let a = gen::random_csr(&mut rng, SPMV_ROWS, SPMV_COLS, 0.2);
    let x = gen::random_vec(&mut rng, SPMV_COLS, 3);
    let reference = a.spmv(&x);
    // XLA golden model over the ELL padding.
    let ell = Ell::from_csr_exact(&a, SPMV_ELL_WIDTH)
        .map_err(|e| format!("{e} (reseed the generator)"))?;
    let colidx_f32: Vec<f32> = ell.colidx.iter().map(|&c| c as f32).collect();
    let out = rt.run(
        "spmv_ell",
        &[
            (&ell.values_f32(), &[SPMV_ROWS, SPMV_ELL_WIDTH][..]),
            (&colidx_f32, &[SPMV_ROWS, SPMV_ELL_WIDTH][..]),
            (&to_f32(&x), &[SPMV_COLS][..]),
        ],
    )?;
    cmp_f32_i16(&out[0], &reference, "spmv: xla vs reference")?;
    // Fabric.
    let cfg = ArchConfig::nexus();
    let built = crate::workloads::spmv::build("spmv", &a, &x, &cfg);
    let fab = run_fabric(cfg, built)?;
    cmp_f32_i16(&out[0], &fab, "spmv: xla vs fabric")?;
    Ok(())
}

fn check_sddmm(rt: &mut GoldenRuntime, seed: u64) -> Result<()> {
    let mut rng = SplitMix64::new(seed ^ 0x52);
    let mask = crate::workloads::binary_mask(&mut rng, SDDMM_M, SDDMM_N, 0.3);
    let a = gen::random_dense(&mut rng, SDDMM_M, SDDMM_K, 3);
    let b = gen::random_dense(&mut rng, SDDMM_K, SDDMM_N, 3);
    let mask_dense = mask.to_dense();
    let out = rt.run(
        "sddmm",
        &[
            (&to_f32(&mask_dense.data), &[SDDMM_M, SDDMM_N][..]),
            (&to_f32(&a.data), &[SDDMM_M, SDDMM_K][..]),
            (&to_f32(&b.data), &[SDDMM_K, SDDMM_N][..]),
        ],
    )?;
    // XLA emits the dense masked product; reference/fabric report values at
    // mask positions in row-major order.
    let reference = mask.sddmm(&a, &b).to_dense();
    cmp_f32_i16(&out[0], &reference.data, "sddmm: xla vs reference")?;
    let cfg = ArchConfig::nexus();
    let built = crate::workloads::sddmm::build(&mask, &a, &b, &cfg);
    let fab = run_fabric(cfg, built)?;
    let mut nz = 0usize;
    for i in 0..mask.rows {
        for (j, _) in mask.row(i) {
            let want = out[0][i * SDDMM_N + j];
            if (want - fab[nz] as f32).abs() > 0.5 {
                return Err(
                    format!("sddmm: xla vs fabric at ({i},{j}): {want} vs {}", fab[nz]).into(),
                );
            }
            nz += 1;
        }
    }
    Ok(())
}

fn check_matmul(rt: &mut GoldenRuntime, seed: u64) -> Result<()> {
    let mut rng = SplitMix64::new(seed ^ 0x53);
    let a = gen::random_dense(&mut rng, MATMUL_N, MATMUL_N, 3);
    let b = gen::random_dense(&mut rng, MATMUL_N, MATMUL_N, 3);
    let reference = a.matmul(&b);
    let out = rt.run(
        "matmul",
        &[
            (&to_f32(&a.data), &[MATMUL_N, MATMUL_N][..]),
            (&to_f32(&b.data), &[MATMUL_N, MATMUL_N][..]),
        ],
    )?;
    cmp_f32_i16(&out[0], &reference.data, "matmul: xla vs reference")?;
    let cfg = ArchConfig::nexus();
    let built = crate::workloads::spmspm::build(
        "matmul",
        &Csr::from_dense(&a),
        &Csr::from_dense(&b),
        &cfg,
    );
    let fab = run_fabric(cfg, built)?;
    cmp_f32_i16(&out[0], &fab, "matmul: xla vs fabric")?;
    Ok(())
}

fn check_spmadd(rt: &mut GoldenRuntime, seed: u64) -> Result<()> {
    let mut rng = SplitMix64::new(seed ^ 0x54);
    let a = gen::random_csr(&mut rng, SPMADD_N, SPMADD_N, 0.3);
    let b = gen::random_csr(&mut rng, SPMADD_N, SPMADD_N, 0.3);
    let out = rt.run(
        "spmadd",
        &[
            (&to_f32(&a.to_dense().data), &[SPMADD_N, SPMADD_N][..]),
            (&to_f32(&b.to_dense().data), &[SPMADD_N, SPMADD_N][..]),
        ],
    )?;
    let reference = a.spadd(&b).to_dense();
    cmp_f32_i16(&out[0], &reference.data, "spmadd: xla vs reference")?;
    let cfg = ArchConfig::nexus();
    let built = crate::workloads::spadd::build(&a, &b, &cfg);
    let fab = run_fabric(cfg, built)?;
    cmp_f32_i16(&out[0], &fab, "spmadd: xla vs fabric")?;
    Ok(())
}
