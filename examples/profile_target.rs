//! Profiling target: the full suite compiled once, executed ten times on
//! one reusable `Machine` — the compile-cache + fabric-reset hot path.

use nexus::machine::Machine;

fn main() {
    let specs = nexus::workloads::suite(1);
    let cfg = nexus::config::ArchConfig::nexus();
    let mut machine = Machine::new(cfg);
    let compiled: Vec<_> = specs
        .iter()
        .map(|s| machine.compile(s).expect("compile"))
        .collect();
    for _ in 0..10 {
        for c in &compiled {
            machine.execute(c).expect("run");
        }
    }
}
