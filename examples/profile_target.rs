fn main() {
    let specs = nexus::workloads::suite(1);
    let cfg = nexus::config::ArchConfig::nexus();
    let built: Vec<_> = specs.iter().map(|s| s.build(&cfg)).collect();
    for _ in 0..10 {
        for b in &built {
            let mut f = nexus::fabric::NexusFabric::new(cfg.clone());
            nexus::workloads::run_on_fabric(&mut f, b).expect("run");
        }
    }
}
