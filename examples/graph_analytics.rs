//! Graph analytics on a contact network: the §4.2 infect-dublin scenario.
//!
//! A synthetic face-to-face contact graph (matched to infect-dublin's
//! published size at fabric scale) is traced with BFS (infection waves),
//! SSSP (weighted contact durations) and PageRank (super-spreader ranking),
//! all executing as asynchronous AM relaxations with conditional
//! re-emission on the Nexus fabric.
//!
//! ```sh
//! cargo run --release --example graph_analytics
//! ```

use nexus::config::ArchConfig;
use nexus::fabric::NexusFabric;
use nexus::tensor::{graph::INF, Graph};
use nexus::util::SplitMix64;
use nexus::workloads::{graphs, run_on_fabric};

fn main() {
    let mut rng = SplitMix64::new(2026);
    let g = Graph::synthetic_contact(&mut rng, 96, 420);
    println!(
        "contact graph: {} people, {} directed contacts\n",
        g.num_vertices,
        g.num_edges()
    );
    let cfg = ArchConfig::nexus();

    // BFS: how many contact hops until the whole component is reached?
    let built = graphs::build_bfs(&g, 0, &cfg);
    let mut f = NexusFabric::new(cfg.clone());
    let levels = run_on_fabric(&mut f, &built).expect("bfs");
    assert_eq!(levels, built.expected);
    let reached = levels.iter().filter(|&&l| l < INF).count();
    let waves = levels.iter().filter(|&&l| l < INF).max().unwrap();
    println!(
        "BFS     patient zero reaches {reached}/{} people in {waves} waves \
         ({} cycles, {:.1}% util, {:.0}% in-network)",
        g.num_vertices,
        f.stats.cycles,
        100.0 * f.stats.utilization(),
        100.0 * f.stats.in_network_fraction()
    );

    // SSSP: weighted by contact duration.
    let built = graphs::build_sssp(&g, 0, &cfg);
    let mut f = NexusFabric::new(cfg.clone());
    let dist = run_on_fabric(&mut f, &built).expect("sssp");
    assert_eq!(dist, built.expected);
    let far = dist.iter().filter(|&&d| d < INF).max().unwrap();
    println!(
        "SSSP    farthest weighted distance {far} ({} cycles, relaxations settle asynchronously)",
        f.stats.cycles
    );

    // PageRank: who are the super-spreaders?
    let built = graphs::build_pagerank(&g, 3, &cfg);
    let mut f = NexusFabric::new(cfg);
    let rank = run_on_fabric(&mut f, &built).expect("pagerank");
    assert_eq!(rank, built.expected);
    let mut order: Vec<usize> = (0..g.num_vertices).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(rank[v]));
    println!(
        "PageRank top-5 super-spreaders: {:?} ({} cycles, 3 host-synchronized tiles)",
        &order[..5],
        f.stats.cycles
    );
}
