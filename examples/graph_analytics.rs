//! Graph analytics on a contact network: the §4.2 infect-dublin scenario.
//!
//! A synthetic face-to-face contact graph (matched to infect-dublin's
//! published size at fabric scale) is traced with BFS (infection waves),
//! SSSP (weighted contact durations) and PageRank (super-spreader ranking),
//! all executing as asynchronous AM relaxations with conditional
//! re-emission on one reusable fabric `Machine` (reset between kernels,
//! never reallocated).
//!
//! ```sh
//! cargo run --release --example graph_analytics
//! ```

use nexus::config::ArchConfig;
use nexus::machine::Machine;
use nexus::tensor::{graph::INF, Graph};
use nexus::util::SplitMix64;
use nexus::workloads::Spec;

fn main() {
    let mut rng = SplitMix64::new(2026);
    let g = Graph::synthetic_contact(&mut rng, 96, 420);
    println!(
        "contact graph: {} people, {} directed contacts\n",
        g.num_vertices,
        g.num_edges()
    );
    let mut machine = Machine::new(ArchConfig::nexus());

    // BFS: how many contact hops until the whole component is reached?
    let exec = machine
        .run(&Spec::Bfs { g: g.clone(), src: 0 })
        .expect("bfs");
    let levels = &exec.outputs;
    let s = exec.stats.as_ref().expect("fabric stats");
    let reached = levels.iter().filter(|&&l| l < INF).count();
    let waves = levels.iter().filter(|&&l| l < INF).max().unwrap();
    println!(
        "BFS     patient zero reaches {reached}/{} people in {waves} waves \
         ({} cycles, {:.1}% util, {:.0}% in-network)",
        g.num_vertices,
        s.cycles,
        100.0 * s.utilization(),
        100.0 * s.in_network_fraction()
    );

    // SSSP: weighted by contact duration (same machine, fabric reset).
    let exec = machine
        .run(&Spec::Sssp { g: g.clone(), src: 0 })
        .expect("sssp");
    let far = exec.outputs.iter().filter(|&&d| d < INF).max().unwrap();
    println!(
        "SSSP    farthest weighted distance {far} ({} cycles, relaxations settle asynchronously)",
        exec.cycles()
    );

    // PageRank: who are the super-spreaders?
    let exec = machine
        .run(&Spec::PageRank { g: g.clone(), iters: 3 })
        .expect("pagerank");
    let rank = &exec.outputs;
    let mut order: Vec<usize> = (0..g.num_vertices).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(rank[v]));
    println!(
        "PageRank top-5 super-spreaders: {:?} ({} cycles, 3 host-synchronized tiles)",
        &order[..5],
        exec.cycles()
    );
}
