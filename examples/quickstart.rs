//! Quickstart: compile one sparse workload for the Nexus Machine and run it
//! on the cycle-accurate fabric through the unified `Machine` API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nexus::config::ArchConfig;
use nexus::machine::Machine;
use nexus::tensor::gen;
use nexus::util::SplitMix64;
use nexus::workloads::Spec;

fn main() {
    // 1. A sparse matrix and a dense vector (INT16, like the fabric).
    let mut rng = SplitMix64::new(42);
    let a = gen::skewed_csr(&mut rng, 32, 32, 0.25); // 75% sparse, skewed rows
    let x = gen::random_vec(&mut rng, 32, 3);
    let nnz = a.nnz();

    // 2. The Table 1 architecture: 4x4 INT16 PEs, 1KB SRAM + 1KB AM queue
    //    per PE, west-first adaptive mesh, en-route execution enabled. The
    //    machine owns one reusable fabric instance.
    let mut machine = Machine::new(ArchConfig::nexus());

    // 3. Compile: partition tensors (Algorithm 1), generate static AMs, and
    //    the replicated config-memory chain LOAD -> MUL -> ACCUM. Compiles
    //    are cached: re-running this workload skips this step.
    let compiled = machine
        .compile(&Spec::Spmv { a, x })
        .expect("compile spmv");
    println!(
        "compiled {} static AMs for {} nonzeros",
        compiled.static_am_count(),
        nnz
    );

    // 4. Execute to drain; the machine validates the outputs against the
    //    software reference (mismatches surface as typed ExecErrors).
    let exec = machine.execute(&compiled).expect("fabric run");
    assert!(exec.validated(), "fabric output must match reference");

    let s = exec.stats.as_ref().expect("fabric stats");
    println!("y[0..8] = {:?}", &exec.outputs[..8]);
    println!("cycles            {}", s.cycles);
    println!("ALU ops           {} ({} executed en-route, {:.1}%)",
        s.alu_ops, s.enroute_ops, 100.0 * s.in_network_fraction());
    println!("fabric utilization {:.1}%", 100.0 * s.utilization());
    println!("load balance CV    {:.3} (0 = perfect)", s.load_cv());
    println!("off-chip traffic   {} bytes", s.offchip_bytes);
}
