//! Quickstart: compile one sparse workload for the Nexus Machine and run it
//! on the cycle-accurate fabric.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use nexus::config::ArchConfig;
use nexus::fabric::NexusFabric;
use nexus::tensor::gen;
use nexus::util::SplitMix64;
use nexus::workloads::{run_on_fabric, spmv};

fn main() {
    // 1. A sparse matrix and a dense vector (INT16, like the fabric).
    let mut rng = SplitMix64::new(42);
    let a = gen::skewed_csr(&mut rng, 32, 32, 0.25); // 75% sparse, skewed rows
    let x = gen::random_vec(&mut rng, 32, 3);

    // 2. The Table 1 architecture: 4x4 INT16 PEs, 1KB SRAM + 1KB AM queue
    //    per PE, west-first adaptive mesh, en-route execution enabled.
    let cfg = ArchConfig::nexus();

    // 3. Compile: partition tensors (Algorithm 1), generate static AMs, and
    //    the replicated config-memory chain LOAD -> MUL -> ACCUM.
    let built = spmv::build("quickstart-spmv", &a, &x, &cfg);
    println!(
        "compiled {} static AMs for {} nonzeros",
        a.nnz(),
        a.nnz()
    );

    // 4. Execute to drain and check against the software reference.
    let mut fabric = NexusFabric::new(cfg);
    let y = run_on_fabric(&mut fabric, &built).expect("fabric run");
    assert_eq!(y, built.expected, "fabric output must match reference");

    let s = &fabric.stats;
    println!("y[0..8] = {:?}", &y[..8]);
    println!("cycles            {}", s.cycles);
    println!("ALU ops           {} ({} executed en-route, {:.1}%)",
        s.alu_ops, s.enroute_ops, 100.0 * s.in_network_fraction());
    println!("fabric utilization {:.1}%", 100.0 * s.utilization());
    println!("load balance CV    {:.3} (0 = perfect)", s.load_cv());
    println!("off-chip traffic   {} bytes", s.offchip_bytes);
}
