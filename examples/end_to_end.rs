//! End-to-end driver: a pruned-ResNet-50-like sparse inference block runs
//! through the **entire stack** — L1/L2 golden models (AOT-compiled XLA
//! artifacts via PJRT) cross-validate the L3 cycle-accurate fabric, then
//! the full five-architecture roster reproduces the paper's headline
//! numbers (§5: ≈1.9x performance and ≈1.7x utilization vs the Generic
//! CGRA on irregular workloads).
//!
//! Requires `make artifacts` for the golden-model stage (skipped with a
//! notice otherwise). Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use nexus::baselines::{roster, RunResult};
use nexus::coordinator;
use nexus::machine::{ExecError, Machine};
use nexus::workloads::suite;

fn main() {
    // Stage 1 — golden-model cross-validation (L2 XLA artifacts vs L3
    // fabric vs software reference), via the PJRT CPU client.
    let dir = nexus::runtime::artifacts_dir();
    if dir.join("spmv_ell.hlo.txt").exists() {
        println!("== stage 1: golden-model cross-validation (PJRT) ==");
        for (name, status) in nexus::golden::check_all(&dir, 1).expect("golden") {
            println!("  {name:<12} {status}");
        }
    } else {
        println!("== stage 1 skipped: run `make artifacts` for golden models ==");
    }

    // Stage 2 — the sparse-inference block on all five architectures, each
    // behind a reusable Machine session.
    println!("\n== stage 2: pruned-ResNet-50-like block, 5-architecture roster ==");
    let specs = suite(1);
    let mut machines: Vec<Machine> = roster().into_iter().map(Machine::from_backend).collect();
    let block: Vec<_> = specs
        .iter()
        .filter(|s| {
            let n = s.name();
            // conv -> matmul -> sparse layers of the pruned block
            n == "Conv" || n == "MatMul" || n.starts_with("SpMV") || n.starts_with("SpMSpM")
        })
        .collect();
    println!(
        "{:<14}{:>12}{:>12}{:>13}{:>13}",
        "workload", "arch", "cycles", "ops/cycle", "utilization"
    );
    let mut per_arch: std::collections::HashMap<&str, Vec<RunResult>> = Default::default();
    for spec in &block {
        for m in &mut machines {
            let r = match m.run(spec) {
                Ok(e) => e.result,
                Err(ExecError::Unsupported { .. }) => continue,
                Err(e) => panic!("{e}"),
            };
            println!(
                "{:<14}{:>12}{:>12}{:>13.3}{:>12.1}%",
                r.workload,
                r.arch,
                r.cycles,
                r.perf(),
                r.utilization * 100.0
            );
            per_arch.entry(r.arch).or_default().push(r);
        }
    }

    // Stage 3 — headline metrics over the full suite.
    println!("\n== stage 3: headline metrics (full 13-workload suite) ==");
    let m = coordinator::run_matrix(1);
    let perf = m.geomean_speedup("Nexus", "GenericCGRA", None);
    let perf_sparse = m.geomean_speedup("Nexus", "GenericCGRA", Some("sparse"));
    let vs_tia = m.geomean_speedup("Nexus", "TIA", None);
    let util = |arch: &str| {
        let mut v = Vec::new();
        for wi in 0..m.workloads.len() {
            if let Some(r) = m.get(wi, arch) {
                v.push(r.utilization);
            }
        }
        nexus::util::mean(&v)
    };
    let u_nexus = util("Nexus");
    let u_cgra = util("GenericCGRA");
    println!("  perf geomean   Nexus/GenericCGRA : {perf:.2}x   (paper: ~1.9x; sparse-only {perf_sparse:.2}x)");
    println!("  perf geomean   Nexus/TIA         : {vs_tia:.2}x  (paper: part of the 1.35x-avg claim)");
    println!(
        "  utilization    Nexus {:.1}% vs CGRA {:.1}% : {:.2}x   (paper: ~1.7x)",
        u_nexus * 100.0,
        u_cgra * 100.0,
        u_nexus / u_cgra
    );
    assert!(perf > 1.0, "Nexus must beat the Generic CGRA overall");
    assert!(u_nexus > u_cgra, "Nexus must beat CGRA utilization");
    println!("\nall stages passed — record in EXPERIMENTS.md");
}
