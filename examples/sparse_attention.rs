//! Sparse attention (SDDMM) on the edge: the §4.2 ViTCoD-style scenario.
//!
//! A vision-transformer attention score block `S = mask ⊙ (Q · Kᵀ)` with a
//! 70%-sparse binary attention mask is compiled onto Nexus Machine, TIA and
//! the systolic baseline; the example reports who wins and why — this is the
//! workload the paper's three-destination AM format (§3.2) was sized for.
//!
//! ```sh
//! cargo run --release --example sparse_attention
//! ```

use nexus::baselines::{systolic::Systolic, FabricArch};
use nexus::machine::{Backend, Machine};
use nexus::tensor::gen;
use nexus::util::SplitMix64;
use nexus::workloads::{binary_mask, Spec};

fn main() {
    let mut rng = SplitMix64::new(7);
    // Q: 32 queries x 16 dims; K^T: 16 x 32 keys; 70%-sparse mask.
    let mask = binary_mask(&mut rng, 32, 32, 0.3);
    let q = gen::random_dense(&mut rng, 32, 16, 3);
    let kt = gen::random_dense(&mut rng, 16, 32, 3);
    println!(
        "attention block: 32x32 scores, mask sparsity {:.0}%, {} useful dot products\n",
        mask.sparsity() * 100.0,
        mask.nnz()
    );

    let spec = Spec::Sddmm { mask, a: q, b: kt };
    println!(
        "{:<14}{:>10}{:>14}{:>14}{:>12}",
        "arch", "cycles", "ops/cycle", "utilization", "in-net %"
    );
    let backends: Vec<Box<dyn Backend>> = vec![
        Box::new(Systolic::default()),
        Box::new(FabricArch::tia()),
        Box::new(FabricArch::tia_valiant()),
        Box::new(FabricArch::nexus()),
    ];
    let mut base = None;
    let mut nexus_perf = None;
    for backend in backends {
        let mut m = Machine::from_backend(backend);
        let e = m.run(&spec).expect("sddmm runs everywhere");
        let r = &e.result;
        match m.name() {
            "TIA" => base = Some(r.perf()),
            "Nexus" => nexus_perf = Some(r.perf()),
            _ => {}
        }
        println!(
            "{:<14}{:>10}{:>14.3}{:>13.1}%{:>11.1}%",
            r.arch,
            r.cycles,
            r.perf(),
            r.utilization * 100.0,
            r.in_network_frac * 100.0
        );
    }
    // The headline mechanism: en-route execution converts NoC transit into
    // compute, beating the data-local TIA on the same fabric.
    println!(
        "\nNexus vs TIA speedup: {:.2}x (mask-position dot products, same ALU count)",
        nexus_perf.unwrap() / base.unwrap()
    );
}
