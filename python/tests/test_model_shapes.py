"""L2 model checks: every registered model lowers to HLO text, keeps its
declared shapes, and agrees with the oracle composition."""

import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from compile import model  # noqa: E402
from compile.aot import to_hlo_text  # noqa: E402
from compile.kernels import ref  # noqa: E402


def test_all_models_lower_to_hlo_text():
    import jax

    for name, (fn, example_args) in model.MODELS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        assert "HloModule" in text, name
        # The text must be parseable interchange: ENTRY computation present.
        assert "ENTRY" in text, name


def test_model_shapes_match_golden_rs_constants():
    # These constants are mirrored in rust/src/golden.rs; a drift here
    # silently breaks the cross-language check, so pin them.
    assert (model.SPMV_ROWS, model.SPMV_COLS, model.SPMV_ELL_WIDTH) == (64, 64, 32)
    assert (model.SDDMM_M, model.SDDMM_K, model.SDDMM_N) == (32, 16, 32)
    assert model.MATMUL_N == 24
    assert model.SPMADD_N == 64


def test_models_agree_with_oracles_end_to_end():
    rng = np.random.default_rng(7)

    def ints(shape):
        return rng.integers(-3, 4, size=shape).astype(np.float32)

    v = ints((model.SPMV_ROWS, model.SPMV_ELL_WIDTH))
    c = rng.integers(0, model.SPMV_COLS, size=v.shape).astype(np.float32)
    x = ints((model.SPMV_COLS,))
    (y,) = model.spmv_model(v, c, x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref.spmv_ell_ref(v, c, x)))

    mask = (rng.random((model.SDDMM_M, model.SDDMM_N)) < 0.3).astype(np.float32)
    a = ints((model.SDDMM_M, model.SDDMM_K))
    b = ints((model.SDDMM_K, model.SDDMM_N))
    (cc,) = model.sddmm_model(mask, a, b)
    np.testing.assert_array_equal(np.asarray(cc), np.asarray(ref.sddmm_ref(mask, a, b)))

    a = ints((model.MATMUL_N, model.MATMUL_N))
    b = ints((model.MATMUL_N, model.MATMUL_N))
    (mm,) = model.matmul_model(a, b)
    np.testing.assert_array_equal(np.asarray(mm), np.asarray(ref.matmul_ref(a, b)))

    a = ints((model.SPMADD_N, model.SPMADD_N))
    b = ints((model.SPMADD_N, model.SPMADD_N))
    (ss,) = model.spmadd_model(a, b)
    np.testing.assert_array_equal(np.asarray(ss), np.asarray(ref.spmadd_ref(a, b)))
