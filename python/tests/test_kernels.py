"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes/densities with hypothesis.  Inputs are small integers in
f32 so equality is exact."""

import pathlib
import sys

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from compile.kernels import ref  # noqa: E402
from compile.kernels.matmul import matmul  # noqa: E402
from compile.kernels.sddmm import sddmm  # noqa: E402
from compile.kernels.spmadd import spmadd  # noqa: E402
from compile.kernels.spmv_ell import spmv_ell  # noqa: E402

SETTINGS = dict(max_examples=20, deadline=None)


def _ints(rng, shape, lo=-4, hi=4):
    return rng.integers(lo, hi + 1, size=shape).astype(np.float32)


@given(
    rows_blocks=st.integers(1, 6),
    width=st.integers(1, 24),
    cols=st.integers(1, 48),
    seed=st.integers(0, 2**32 - 1),
)
@settings(**SETTINGS)
def test_spmv_ell_matches_ref(rows_blocks, width, cols, seed):
    rng = np.random.default_rng(seed)
    rows = 8 * rows_blocks
    values = _ints(rng, (rows, width))
    colidx = rng.integers(0, cols, size=(rows, width)).astype(np.float32)
    # Emulate ELL padding: zero-valued slots may point anywhere; also zero
    # a random suffix of each row like real padding does.
    x = _ints(rng, (cols,))
    got = np.asarray(spmv_ell(values, colidx, x))
    want = np.asarray(ref.spmv_ell_ref(values, colidx, x))
    np.testing.assert_array_equal(got, want)


@given(
    mb=st.integers(1, 3),
    nb=st.integers(1, 3),
    k=st.integers(1, 24),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**32 - 1),
)
@settings(**SETTINGS)
def test_sddmm_matches_ref(mb, nb, k, density, seed):
    rng = np.random.default_rng(seed)
    m, n = 16 * mb, 16 * nb
    mask = (rng.random((m, n)) < density).astype(np.float32)
    a = _ints(rng, (m, k))
    b = _ints(rng, (k, n))
    got = np.asarray(sddmm(mask, a, b))
    want = np.asarray(ref.sddmm_ref(mask, a, b))
    np.testing.assert_array_equal(got, want)
    # Sparsity is respected: zero mask slots stay exactly zero.
    assert np.all(got[mask == 0.0] == 0.0)


@given(
    mb=st.integers(1, 4),
    nb=st.integers(1, 4),
    kb=st.integers(1, 4),
    seed=st.integers(0, 2**32 - 1),
)
@settings(**SETTINGS)
def test_matmul_matches_ref(mb, nb, kb, seed):
    rng = np.random.default_rng(seed)
    m, n, k = 8 * mb, 8 * nb, 8 * kb
    a = _ints(rng, (m, k))
    b = _ints(rng, (k, n))
    got = np.asarray(matmul(a, b))
    want = np.asarray(ref.matmul_ref(a, b))
    np.testing.assert_array_equal(got, want)


@given(
    rb=st.integers(1, 6),
    cols=st.integers(1, 64),
    seed=st.integers(0, 2**32 - 1),
)
@settings(**SETTINGS)
def test_spmadd_matches_ref(rb, cols, seed):
    rng = np.random.default_rng(seed)
    rows = 8 * rb
    a = _ints(rng, (rows, cols))
    b = _ints(rng, (rows, cols))
    got = np.asarray(spmadd(a, b))
    np.testing.assert_array_equal(got, np.asarray(ref.spmadd_ref(a, b)))


def test_spmv_padding_slots_are_harmless():
    # Explicit ELL-padding semantics: value-0 slots contribute nothing even
    # when their column index aliases a real column.
    values = np.zeros((8, 4), np.float32)
    values[0, 0] = 3.0
    colidx = np.zeros((8, 4), np.float32)
    colidx[0, 0] = 2
    x = np.arange(5, dtype=np.float32)
    y = np.asarray(spmv_ell(values, colidx, x))
    assert y[0] == 3.0 * x[2]
    assert np.all(y[1:] == 0.0)
