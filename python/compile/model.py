"""L2 golden models: the JAX compute graphs the rust runtime validates the
fabric against.  Each model is a thin jax function over the L1 Pallas
kernels; ``aot.py`` lowers every entry of ``MODELS`` to HLO text once at
build time.  Shapes are fixed here (XLA AOT requires static shapes) and
mirrored in ``rust/src/golden.rs``.
"""

import jax.numpy as jnp

from compile.kernels.matmul import matmul
from compile.kernels.sddmm import sddmm
from compile.kernels.spmadd import spmadd
from compile.kernels.spmv_ell import spmv_ell

# Artifact shapes — keep in sync with rust/src/golden.rs.
SPMV_ROWS, SPMV_COLS, SPMV_ELL_WIDTH = 64, 64, 32
SDDMM_M, SDDMM_K, SDDMM_N = 32, 16, 32
MATMUL_N = 24
SPMADD_N = 64


def spmv_model(values, colidx, x):
    return (spmv_ell(values, colidx, x),)


def sddmm_model(mask, a, b):
    return (sddmm(mask, a, b),)


def matmul_model(a, b):
    return (matmul(a, b),)


def spmadd_model(a, b):
    return (spmadd(a, b),)


def _s(*shape):
    import jax

    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: name -> (fn, example_args)
MODELS = {
    "spmv_ell": (
        spmv_model,
        (
            _s(SPMV_ROWS, SPMV_ELL_WIDTH),
            _s(SPMV_ROWS, SPMV_ELL_WIDTH),
            _s(SPMV_COLS),
        ),
    ),
    "sddmm": (
        sddmm_model,
        (_s(SDDMM_M, SDDMM_N), _s(SDDMM_M, SDDMM_K), _s(SDDMM_K, SDDMM_N)),
    ),
    "matmul": (matmul_model, (_s(MATMUL_N, MATMUL_N), _s(MATMUL_N, MATMUL_N))),
    "spmadd": (spmadd_model, (_s(SPMADD_N, SPMADD_N), _s(SPMADD_N, SPMADD_N))),
}
