"""AOT lowering: JAX/Pallas golden models -> artifacts/<name>.hlo.txt.

Runs once at build time (``make artifacts``); the rust binary loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client.  Interchange is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).
"""

import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from compile.model import MODELS  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    out_dir = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "artifacts")
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, (fn, example_args) in MODELS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"  {name:<12} -> {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
