"""L1 Pallas kernel: SDDMM as a masked dense matmul.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on a TPU the
profitable SDDMM strategy at moderate density is to run the dense product
on the MXU and apply sparsity as an elementwise mask (the ViTCoD-style
attention masks the paper evaluates are exactly this shape).  The kernel
tiles the output into ``[TILE_M, TILE_N]`` MXU-aligned blocks; each block
computes ``mask_block * (A_row_panel @ B_col_panel)``.

MXU notes: TILE_M = TILE_N = 16 divides the artifact shapes and maps onto
the 128x128 systolic array in one pass per block at these sizes; K stays
unsplit (K=16) so no accumulator carries across grid steps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 16
TILE_N = 16


def _kernel(mask_ref, a_ref, b_ref, o_ref):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = mask_ref[...] * acc


@functools.partial(jax.jit, static_argnames=())
def sddmm(mask, a, b):
    """``C = mask * (A @ B)`` with a binary mask."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and mask.shape == (m, n)
    assert m % TILE_M == 0 and n % TILE_N == 0
    grid = (m // TILE_M, n // TILE_N)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_M, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, TILE_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(mask, a, b)
