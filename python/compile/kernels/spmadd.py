"""L1 Pallas kernel: element-wise sparse-matrix addition (densified).

Trivial VPU kernel, blocked row-wise so arbitrary matrix heights stream
through a fixed VMEM footprint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=())
def spmadd(a, b):
    assert a.shape == b.shape
    rows, cols = a.shape
    assert rows % ROW_BLOCK == 0
    grid = (rows // ROW_BLOCK,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, cols), lambda r: (r, 0)),
            pl.BlockSpec((ROW_BLOCK, cols), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, cols), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a, b)
