"""L1 Pallas kernel: ELL-padded SpMV.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the fabric's
data-driven CSR gather does not map onto the MXU, so the golden kernel
works on the ELL padding — a dense ``[rows, width]`` slab of values and
column indices.  The gather becomes a vectorized ``take`` on the VPU and
the reduction a lane-wise multiply-add, with BlockSpec tiling rows into
VMEM-sized blocks.

TPU sizing notes (the structural targets we optimize for; interpret=True
gives CPU-numpy timing only, so we reason from footprints):

- VMEM per block = ``ROW_BLOCK * width * 4B * 2`` (values + gathered x)
  plus the full ``x`` vector, broadcast to every block.  For the artifact
  shape (64x32 + x[64]) that is ~18KB, far under the ~16MB VMEM budget;
  ROW_BLOCK=8 keeps the sublane dimension aligned (8 f32 sublanes).
- The kernel is VPU-bound (no matmul): roofline is the HBM stream of the
  ELL slabs, ~2 flops/byte.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _kernel(x_ref, values_ref, colidx_ref, o_ref):
    """One row-block: gather x by colidx, multiply, reduce across width."""
    vals = values_ref[...]  # [ROW_BLOCK, width]
    idx = colidx_ref[...].astype(jnp.int32)  # [ROW_BLOCK, width]
    x = x_ref[...]  # [cols] (whole vector in VMEM)
    gathered = x[idx]  # VPU gather
    o_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=())
def spmv_ell(values, colidx, x):
    """``y = A @ x`` with A in ELL form (values/colidx ``[rows, width]``)."""
    rows, _width = values.shape
    assert rows % ROW_BLOCK == 0, f"rows {rows} must be a multiple of {ROW_BLOCK}"
    grid = (rows // ROW_BLOCK,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda r: (0,)),  # x: replicated
            pl.BlockSpec((ROW_BLOCK, values.shape[1]), lambda r: (r, 0)),
            pl.BlockSpec((ROW_BLOCK, values.shape[1]), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((rows,), values.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, values, colidx)
