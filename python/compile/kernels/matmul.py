"""L1 Pallas kernel: tiled dense matmul (MXU blocks).

The dense-workload golden kernel: output tiled ``[TILE, TILE]``, K
traversed in the innermost grid dimension with a VMEM accumulator —
the canonical TPU matmul schedule (HBM->VMEM panels, MXU per tile).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 8


def _kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def matmul(a, b):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % TILE == 0 and n % TILE == 0 and k % TILE == 0
    grid = (m // TILE, n // TILE, k // TILE)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
