"""Pure-jnp oracles for the Pallas kernels.

These are the L1 correctness references: every Pallas kernel in this
package must agree with its oracle bit-for-bit on integer-valued f32
inputs (pytest + hypothesis sweep shapes and densities in
``python/tests/``).  The rust simulator is in turn validated against the
AOT-lowered L2 models built from these kernels (``nexus golden``).
"""

import jax.numpy as jnp


def spmv_ell_ref(values, colidx, x):
    """ELL-padded SpMV: ``y[r] = sum_s values[r, s] * x[colidx[r, s]]``.

    Padding slots carry value 0 (and column 0), so they contribute nothing.
    ``colidx`` arrives as f32 (the PJRT input path feeds f32 buffers) and is
    cast in-graph.
    """
    idx = colidx.astype(jnp.int32)
    gathered = x[idx]  # [rows, width]
    return jnp.sum(values * gathered, axis=1)


def sddmm_ref(mask, a, b):
    """Masked dense matmul: ``C = mask * (A @ B)`` (mask is binary)."""
    return mask * (a @ b)


def matmul_ref(a, b):
    """Plain dense matmul."""
    return a @ b


def spmadd_ref(a, b):
    """Element-wise addition of (densified) sparse matrices."""
    return a + b
